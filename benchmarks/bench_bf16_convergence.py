"""bf16 end-to-end accuracy study: CP-ALS convergence with bf16 gathers.

The bf16-gather backends are validated at the kernel/mode-step level
(≈ (N−1)·2⁻⁸ relative error per MTTKRP), but a decomposition runs tens
of sweeps: does that per-step rounding accumulate, stall the fit, or
wash out? This bench answers the open ROADMAP item by running the same
distributed CP-ALS (same tensor, same seed, same backend — the
in-kernel-gather fused kernel) twice, with ``gather_dtype="float32"``
vs ``"bfloat16"``, and recording fit-vs-sweeps for both.

Output (``experiments/bench/BENCH_bf16_convergence.json``): one row per
(tensor, rank) with the two fit traces, the final-fit gap, and the
largest per-sweep gap. The ``docs/kernels.md`` "bf16 end-to-end
accuracy" note states the recommendation this data supports: bf16
gathers are safe when the fit gap stays within the ALS convergence
tolerance (they shift the fixed point by ~1e-3 at most on these
tensors), and should stay opt-in for tight-tolerance decompositions.

Wall time is interpret-mode emulation and is not recorded — the fit
traces are the record.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed as dist
from repro.core.cpals import cp_als_distributed
from repro.core.flycoo import build_flycoo

from .common import bench_tensor, row, write_bench_json

# Every fused-family backend honors gather_dtype; the in-kernel gather
# backend is the dispatch's first choice and the one whose bf16 variant
# also halves the *resident* factor set, so it is the one measured.
_BACKEND = "pallas_fused_gather"


def _fit_trace(ft, rank: int, mesh: Mesh, iters: int,
               gather_dtype: str) -> list[float]:
    res = cp_als_distributed(
        ft, rank, mesh, iters=iters, seed=1, tol=0.0, backend=_BACKEND,
        tile_rows=8, gather_dtype=gather_dtype)
    return [float(f) for f in res.fits]


def run(quick: bool = True, scale: float | None = None):
    scale = (0.1 if quick else 0.25) if scale is None else scale
    mesh = Mesh(np.array(jax.devices()), (dist.AXIS,))
    iters = 5 if quick else 10
    rows = []
    if quick:
        cases = (("nell-2", (16,)), ("enron", (16,)))
    else:
        cases = (("nell-2", (16, 64)), ("enron", (16, 64)))
    for name, ranks in cases:
        t = bench_tensor(name, scale=scale)
        ft = build_flycoo(t, num_workers=len(jax.devices()))
        for rank in ranks:
            fits32 = _fit_trace(ft, rank, mesh, iters, "float32")
            fits16 = _fit_trace(ft, rank, mesh, iters, "bfloat16")
            gaps = [abs(a - b) for a, b in zip(fits32, fits16)]
            rows.append(row(
                "bf16_convergence", tensor=name, nmodes=t.nmodes,
                nnz=t.nnz, rank=rank, sweeps=len(fits32),
                backend=_BACKEND,
                fits_fp32=[round(f, 6) for f in fits32],
                fits_bf16=[round(f, 6) for f in fits16],
                final_fit_gap=round(gaps[-1], 6),
                max_sweep_fit_gap=round(max(gaps), 6),
                bf16_converged_within_1e2=bool(gaps[-1] < 1e-2),
            ))
    write_bench_json("bf16_convergence", rows)
    return rows
