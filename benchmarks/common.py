"""Shared benchmark utilities: timing, CSV rows, standard tensors.

Benchmarks measure on the REAL host CPU — legitimate here because the
paper's target is a CPU (the TPU mapping is validated by the dry-run +
roofline instead). On this 1-core container, *parallel wall-clock speedup*
is not measurable, so distribution-sensitive figures (6, 7) report counted
work-balance metrics (max/mean load = the paper's speedup bound) alongside
wall time, and figure 8 counts exact traffic bytes.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core.tensors import frostt_like

BENCH_TENSORS = ("nell-2", "nell-1", "flickr", "delicious", "vast", "enron")


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_tensor(name: str, scale: float = 0.25, seed: int = 0):
    return frostt_like(name, seed=seed, scale=scale)


def row(bench: str, **kv) -> dict:
    return dict(bench=bench, **kv)


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        items = ",".join(f"{k}={v}" for k, v in r.items())
        print(items, flush=True)
