"""Shared benchmark utilities: timing, CSV rows, standard tensors.

Benchmarks measure on the REAL host CPU — legitimate here because the
paper's target is a CPU (the TPU mapping is validated by the dry-run +
roofline instead). On this 1-core container, *parallel wall-clock speedup*
is not measurable, so distribution-sensitive figures (6, 7) report counted
work-balance metrics (max/mean load = the paper's speedup bound) alongside
wall time, and figure 8 counts exact traffic bytes.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

from repro.core.tensors import frostt_like

BENCH_TENSORS = ("nell-2", "nell-1", "flickr", "delicious", "vast", "enron")

BENCH_OUT_DIR = os.path.join("experiments", "bench")


def write_bench_json(name: str, rows: list[dict],
                     out_dir: str | None = None) -> str:
    """Write machine-readable rows to ``<out_dir>/BENCH_<name>.json``.

    The one shared writer every benchmark uses (no ad-hoc per-module
    writers), so downstream tooling can glob ``BENCH_*.json``.
    ``out_dir=None`` resolves to the module-level ``BENCH_OUT_DIR``,
    which ``benchmarks.run --out`` redirects so row dumps and BENCH
    artifacts land in one place.
    """
    out_dir = BENCH_OUT_DIR if out_dir is None else out_dir
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return path


def exchange_sizing(ft, num_workers: int) -> dict:
    """Allocated all_to_all payload bytes for a FLYCOO tensor, both ways.

    ``uniform``: every transition padded to the max capacity (the old
    ``DynasorRuntime.bucket_cap`` / ``uniform_cap=True`` sizing).
    ``per_transition``: each transition sized to its own
    ``remap_capacities`` bound (the tuned default). The single source of
    truth for bench_remap_traffic and bench_dispatch.
    """
    from repro.core.remap import remap_capacities

    caps = remap_capacities(ft)
    elem_bytes = 4 * ft.nmodes + 4          # coords + value
    per_transition = sum(num_workers * num_workers * c * elem_bytes
                         for c in caps)
    uniform = (ft.nmodes * num_workers * num_workers * max(caps)
               * elem_bytes)
    return dict(
        caps=list(map(int, caps)),
        elem_bytes=elem_bytes,
        uniform_bytes=uniform,
        per_transition_bytes=per_transition,
        savings_frac=1.0 - per_transition / max(uniform, 1),
    )


def pr2_static_backend(nmodes: int, rank: int, blk: int,
                       tile_rows: int) -> str:
    """The PR-2 static dispatch rule, reconstructed for baseline rows.

    Before the rank-tiled kernel existed, `select_backend` had exactly
    two MXU rules: fused iff the *full* padded-rank working set fits the
    VMEM budget, else materialize in HBM. bench_rank and bench_dispatch
    both record this historical decision next to the current one — one
    definition here so the two benches can never disagree about what
    \"PR-2 behavior\" was.
    """
    from repro.kernels.mttkrp import ops as kops

    if rank < kops.MIN_MXU_RANK:
        return "ref"
    if kops.fused_fits_vmem(nmodes, rank, blk, tile_rows):
        return "pallas_fused"
    return "pallas"


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_tensor(name: str, scale: float = 0.25, seed: int = 0):
    return frostt_like(name, seed=seed, scale=scale)


def row(bench: str, **kv) -> dict:
    return dict(bench=bench, **kv)


def print_rows(rows: list[dict]) -> None:
    for r in rows:
        items = ",".join(f"{k}={v}" for k, v in r.items())
        print(items, flush=True)
