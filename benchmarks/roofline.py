"""Paper Fig. 9 + assignment §Roofline: both roofline analyses.

1. ``spmttkrp_roofline`` — arithmetic intensity of the paper's elementwise
   spMTTKRP (Fig. 9): flops/byte per nonzero with and without dynamic
   remapping (Case 1 vs Case 2), per FROSTT profile. Shows the kernel is
   memory-bound (AI « ridge point) and that remap costs <15% extra bytes
   while removing the dense-partials all-reduce.

2. ``collect_dryrun_table`` — aggregates ``experiments/dryrun/*.json``
   into the §Roofline table: per (arch × shape × mesh) the three terms,
   dominant bottleneck, MODEL_FLOPS ratio, and what would move the
   dominant term (heuristic annotation).
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.core.tensors import FROSTT_PROFILES
from repro.launch.mesh import HW

from .common import row, write_bench_json

RIDGE_AI = HW["peak_flops_bf16"] / HW["hbm_bw"]     # ~241 flop/byte on v5e


def spmttkrp_roofline(rank: int = 32):
    rows = []
    for name, prof in FROSTT_PROFILES.items():
        shape, nnz = prof["shape"], prof["nnz"]
        N = len(shape)
        elem = 4 * N + 4
        flops = nnz * N * ((N - 1) + 1) * rank * 2     # Hadamard+scale+add /mode, all modes
        bytes_case1 = (N * (nnz * (N - 1) * rank * 4 + nnz * elem
                            + shape_out_bytes(shape, rank))
                       + N * nnz * elem)               # + remap writes
        # Case 2 (no remap): non-owner modes emit dense partials that must
        # be combined — traffic grows by a full (I_n × R) per worker merge.
        bytes_case2 = N * (nnz * (N - 1) * rank * 4 + nnz * elem
                           + 56 * shape_out_bytes(shape, rank))
        for case, b in (("with_remap", bytes_case1),
                        ("without_remap", bytes_case2)):
            ai = flops / b
            perf_bound = min(HW["peak_flops_bf16"], ai * HW["hbm_bw"])
            rows.append(row("roofline_fig9", tensor=name, rank=rank,
                            case=case, arithmetic_intensity=round(ai, 3),
                            ridge_point=round(RIDGE_AI, 1),
                            memory_bound=bool(ai < RIDGE_AI),
                            bound_gflops=round(perf_bound / 1e9, 1)))
    return rows


def shape_out_bytes(shape, rank):
    return sum(shape) * rank * 4 / len(shape)


def collect_dryrun_table(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") != "ok":
            rows.append(row("roofline_table", cell=os.path.basename(path),
                            status=d.get("status"),
                            reason=d.get("reason", "")[:80]))
            continue
        r = d["roofline"]
        hint = {
            "compute_s": "reduce remat recompute / exploit causal sparsity",
            "memory_s": "cast caches to bf16 / increase arithmetic "
                        "intensity via fusion",
            "collective_s": "re-shard to cut all-gathers / overlap with "
                            "compute",
        }[r["dominant"]]
        rows.append(row(
            "roofline_table", arch=d["arch"], shape=d["shape"],
            mesh=d["mesh"], status="ok",
            compute_ms=round(r["compute_s"] * 1e3, 2),
            memory_ms=round(r["memory_s"] * 1e3, 2),
            collective_ms=round(r["collective_s"] * 1e3, 2),
            dominant=r["dominant"],
            useful_flops_ratio=round(d.get("useful_flops_ratio") or 0, 3),
            peak_hbm_frac=round(d.get("peak_hbm_frac", 0), 3),
            next_lever=hint))
    return rows


def run(quick: bool = True):
    rows = spmttkrp_roofline() + collect_dryrun_table()
    write_bench_json("roofline", rows)
    return rows
